//! Minimal JSON parser — just enough for `artifacts/manifest.json` and the
//! cluster config files. No serde in the offline crate set, so this is a
//! small recursive-descent parser over the JSON grammar (objects, arrays,
//! strings with escapes, numbers, booleans, null). Not streaming; inputs
//! are tiny.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let s = r#"{"models": {"pagerank": [{"n": 256, "k": 8, "file": "pagerank_n256_k8.hlo.txt"}]}}"#;
        let j = parse(s).unwrap();
        let entries = j.get("models").unwrap().get("pagerank").unwrap().as_arr().unwrap();
        assert_eq!(entries[0].get("n").unwrap().as_usize(), Some(256));
        assert_eq!(
            entries[0].get("file").unwrap().as_str(),
            Some("pagerank_n256_k8.hlo.txt")
        );
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested_arrays() {
        let j = parse("[1, [2, 3], {\"x\": []}]").unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }
}
