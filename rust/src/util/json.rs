//! Minimal JSON parser + serializer — just enough for
//! `artifacts/manifest.json`, the cluster config files, and the
//! `BENCH_hotpath.json` emitted by `windgp bench`. No serde in the offline
//! crate set, so this is a small recursive-descent parser over the JSON
//! grammar (objects, arrays, strings with escapes, numbers, booleans,
//! null) and a matching [`Json::dump`] writer. Not streaming; inputs are
//! tiny.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// Serialize to a compact JSON string. Round-trips through [`parse`]
    /// (floats print via Rust's shortest decimal `Display`, which never
    /// emits exponent notation; non-finite numbers serialize as `null`,
    /// the standard JSON stance).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    out.push_str(&format!("{x}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_json_string(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Terse object builder for emitters (`windgp bench`, the export
/// manifest, the serve protocol): `obj(vec![("k", Json::Num(1.0))])`.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_json_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let s = r#"{"models": {"pagerank": [{"n": 256, "k": 8, "file": "pagerank_n256_k8.hlo.txt"}]}}"#;
        let j = parse(s).unwrap();
        let entries = j.get("models").unwrap().get("pagerank").unwrap().as_arr().unwrap();
        assert_eq!(entries[0].get("n").unwrap().as_usize(), Some(256));
        assert_eq!(
            entries[0].get("file").unwrap().as_str(),
            Some("pagerank_n256_k8.hlo.txt")
        );
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested_arrays() {
        let j = parse("[1, [2, 3], {\"x\": []}]").unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn dump_roundtrips_through_parse() {
        let mut obj = BTreeMap::new();
        obj.insert("name".to_string(), Json::Str("tracker/\"hot\" path\n".into()));
        obj.insert("mean_ns".to_string(), Json::Num(1234567.25));
        obj.insert("ok".to_string(), Json::Bool(true));
        obj.insert("none".to_string(), Json::Null);
        obj.insert(
            "list".to_string(),
            Json::Arr(vec![Json::Num(1.0), Json::Num(-2.5), Json::Str("x".into())]),
        );
        let j = Json::Obj(obj);
        let text = j.dump();
        assert_eq!(parse(&text).unwrap(), j);
    }

    #[test]
    fn dump_escapes_control_chars() {
        let j = Json::Str("a\u{1}b".into());
        assert_eq!(j.dump(), "\"a\\u0001b\"");
        assert_eq!(parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn dump_nonfinite_as_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
    }

    #[test]
    fn dump_plain_values() {
        assert_eq!(Json::Null.dump(), "null");
        assert_eq!(Json::Bool(false).dump(), "false");
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Arr(vec![]).dump(), "[]");
        assert_eq!(Json::Obj(BTreeMap::new()).dump(), "{}");
    }
}
