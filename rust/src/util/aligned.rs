//! 32-byte-aligned contiguous storage for SIMD kernel operands.
//!
//! `Vec<T>` only guarantees `align_of::<T>()`, but the AVX2 ELL kernels
//! ([`crate::simulator::simd`]) want every operand array to start on a
//! 32-byte boundary so row strides that are a multiple of the lane width
//! keep *every row* aligned. Over-aligning a `Vec<f32>` in place is not
//! possible without unsafe allocator plumbing (rebuilding via
//! `Vec::from_raw_parts` with a different layout is UB on dealloc), so
//! [`AVec`] owns a `Vec` of 32-byte chunks and exposes the payload as a
//! `[T]` slice via `Deref`/`DerefMut` — call sites index it exactly like
//! the `Vec<T>` it replaces.

use std::marker::PhantomData;
use std::ops::{Deref, DerefMut};

/// Alignment guarantee of [`AVec`]'s base pointer, in bytes (one AVX2
/// register).
pub const ALIGN: usize = 32;

/// 4-byte plain-old-data scalars storable in an [`AVec`]: every bit
/// pattern must be a valid value (so zero-initialized chunks are valid
/// payloads) and the size must divide [`ALIGN`].
pub trait Pod4: Copy + 'static {}
impl Pod4 for f32 {}
impl Pod4 for i32 {}
impl Pod4 for u32 {}

#[derive(Clone, Copy)]
#[repr(C, align(32))]
struct Chunk([u8; ALIGN]);

/// Fixed-length zero-initialized array of `T` whose base address is
/// 32-byte aligned. Grows only by reconstruction ([`AVec::zeroed`]) —
/// the ELL builder sizes it once up front.
#[derive(Clone)]
pub struct AVec<T: Pod4> {
    chunks: Vec<Chunk>,
    len: usize,
    _marker: PhantomData<T>,
}

impl<T: Pod4> AVec<T> {
    /// Allocate `len` zeroed elements (all-zero bytes are a valid `T` by
    /// the [`Pod4`] contract).
    pub fn zeroed(len: usize) -> Self {
        const {
            assert!(std::mem::size_of::<T>() == 4);
            assert!(std::mem::align_of::<T>() <= ALIGN);
        }
        let per_chunk = ALIGN / std::mem::size_of::<T>();
        let chunks = vec![Chunk([0u8; ALIGN]); len.div_ceil(per_chunk)];
        Self { chunks, len, _marker: PhantomData }
    }
}

impl<T: Pod4> Deref for AVec<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        // Safety: the chunk buffer holds at least `len * 4` bytes, the
        // base is 32-byte (>= 4) aligned, and any bit pattern is a valid
        // `T` (Pod4). Lifetime is tied to `&self`.
        unsafe { std::slice::from_raw_parts(self.chunks.as_ptr() as *const T, self.len) }
    }
}

impl<T: Pod4> DerefMut for AVec<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        // Safety: as in `deref`, plus exclusive access via `&mut self`.
        unsafe { std::slice::from_raw_parts_mut(self.chunks.as_mut_ptr() as *mut T, self.len) }
    }
}

impl<T: Pod4 + std::fmt::Debug> std::fmt::Debug for AVec<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_list().entries(self.iter()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_pointer_is_32_byte_aligned() {
        for len in [0usize, 1, 7, 8, 9, 31, 64, 1000] {
            let v: AVec<f32> = AVec::zeroed(len);
            assert_eq!(v.len(), len);
            assert_eq!(v.as_ptr() as usize % ALIGN, 0, "len {len}");
            assert!(v.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn indexing_and_slicing_work_like_vec() {
        let mut v: AVec<i32> = AVec::zeroed(10);
        for i in 0..10 {
            v[i] = i as i32 * 3;
        }
        assert_eq!(v[7], 21);
        assert_eq!(&v[2..4], &[6, 9]);
        let s: &[i32] = &v;
        assert_eq!(s.iter().sum::<i32>(), 135);
    }

    #[test]
    fn clone_copies_payload_and_stays_aligned() {
        let mut v: AVec<u32> = AVec::zeroed(33);
        v[32] = 0xDEAD;
        let c = v.clone();
        assert_eq!(c[32], 0xDEAD);
        assert_eq!(c.as_ptr() as usize % ALIGN, 0);
    }
}
