//! Deterministic, seedable PRNG (SplitMix64) — every randomized component
//! in the library (R-MAT, hash partitioner tie-breaks, SLS edge picks,
//! experiment repetitions) threads one of these through explicitly, so runs
//! are reproducible bit-for-bit from a seed, as §5.1 requires (averaging
//! over 10 seeded runs).

/// SplitMix64: tiny, fast, passes BigCrush for this use. Not cryptographic.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift reduction (biased
    /// by < 2^-32 for the n values used here, which is irrelevant).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    #[inline]
    pub fn next_usize(&mut self, n: usize) -> usize {
        self.next_below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent stream (for per-partition / per-run seeding).
    pub fn fork(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }
}

/// Stable 64-bit hash for vertex ids (used by the hash/DBH partitioners
/// instead of `std::hash` so results are identical across rust versions).
#[inline]
pub fn hash64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = SplitMix64::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            counts[v] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "skewed bucket: {c}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(3);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn hash64_stable_values() {
        // pinned so cross-version drift is caught
        assert_eq!(hash64(0), hash64(0));
        assert_ne!(hash64(1), hash64(2));
    }
}
