# L1: Pallas kernels for the BSP superstep hot-spots (PageRank push SpMV,
# SSSP min-plus relaxation), plus the pure-jnp oracles in ref.py.
from . import ref  # noqa: F401
from .minplus_ell import minplus_ell  # noqa: F401
from .spmv_ell import spmv_ell  # noqa: F401
