"""L1 Pallas kernel: blocked ELL min-plus relaxation (the SSSP hot loop).

One Bellman-Ford round over a row tile:
    y[i] = min(x[i], min_k (wts[i,k] + x[cols[i,k]]))
with masked padding forced to INF so it never wins the min. Same VMEM
tiling story as spmv_ell: x resident, (rows, K) tiles streamed, no branches.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import INF
from .spmv_ell import BLOCK_ROWS


def _minplus_kernel(x_ref, cols_ref, wts_ref, mask_ref, o_ref):
    x = x_ref[...]
    cand = jnp.where(mask_ref[...] > 0, wts_ref[...] + x[cols_ref[...]], INF)
    # rows of the current tile: slice x with the tile's own indices is not
    # needed — x_ref is the full vector, but o_ref block matches the row
    # tile, so gather the diagonal slice via program_id offset.
    i = pl.program_id(0)
    rows = x_ref[pl.dslice(i * o_ref.shape[0], o_ref.shape[0])]
    o_ref[...] = jnp.minimum(rows, jnp.min(cand, axis=1))


@functools.partial(jax.jit, static_argnames=("block_rows",))
def minplus_ell(x, cols, wts, mask, *, block_rows=BLOCK_ROWS):
    """One masked min-plus relaxation round, row-tiled."""
    n, k = cols.shape
    assert x.shape == (n,)
    if n % block_rows != 0:
        block_rows = n  # single-block fallback for small/ragged inputs
    grid = (n // block_rows,)
    return pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),            # x: full
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, cols, wts, mask)
