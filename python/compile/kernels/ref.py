"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle here to float tolerance under pytest (python/tests/).
They are intentionally written in the most direct jnp form — no tiling, no
masking tricks — so a reviewer can check them against the math by eye.

Graph format: ELL (ELLPACK). A local subgraph with N rows is stored as
  cols : int32[N, K]   column index of the k-th incident edge of row i
                       (padding entries point at row 0 — any valid index)
  vals : f32[N, K]     edge weight; exactly 0.0 on padding entries, so the
                       padding contributes nothing to the accumulation
For PageRank push, vals[i, k] = 1 / out_degree(cols[i, k]) on real entries.
For SSSP min-plus, vals holds edge weights and a separate mask marks padding
(padding must contribute +inf, not 0, to a min-reduction).
"""

import jax.numpy as jnp

# Sentinel for min-plus padding; < f32 max, > any real path length. Kept a
# plain python float so Pallas kernels can inline it as a literal instead of
# capturing a traced constant.
INF = 3.0e38


def spmv_ell(x, cols, vals):
    """y[i] = sum_k vals[i,k] * x[cols[i,k]].

    The padded-entry convention (vals==0) makes the gather of arbitrary
    x[cols] harmless.
    """
    return jnp.sum(vals * x[cols], axis=1)


def pagerank_step(x, cols, vals, damping, teleport):
    """One PageRank push superstep on a local ELL block.

    new_rank = damping * (A_hat @ x) + teleport
    where A_hat is the column-normalized adjacency encoded by (cols, vals)
    and teleport already folds (1-d)/N plus the dangling-mass correction —
    both are uniform scalars, computed by the L3 coordinator per superstep.
    """
    return damping * spmv_ell(x, cols, vals) + teleport


def minplus_ell(x, cols, wts, mask):
    """y[i] = min(x[i], min_k (wts[i,k] + x[cols[i,k]]))  (masked).

    mask is 1.0 on real entries and 0.0 on padding; padded lanes are forced
    to INF so they never win the min. This is one round of Bellman-Ford
    relaxation (the SSSP superstep's local compute).
    """
    cand = jnp.where(mask > 0, wts + x[cols], INF)
    return jnp.minimum(x, jnp.min(cand, axis=1))


def degree_ell(vals):
    """Row non-zero count — used to validate padding bookkeeping."""
    return jnp.sum((vals != 0).astype(jnp.int32), axis=1)
