"""L1 Pallas kernel: blocked ELL SpMV (the PageRank-push hot loop).

TPU mapping (see DESIGN.md §Hardware-Adaptation): rows are tiled into
BLOCK_ROWS-sized VMEM blocks via BlockSpec; the dense input vector x stays
resident in VMEM for every block (it is the reuse-heavy operand, the analog
of keeping the frontier in shared memory on GPU). Per block the kernel does
one gather x[cols] and one masked multiply-accumulate — a VPU-friendly
(BLOCK_ROWS, K) elementwise fma followed by a lane reduction. Padding is
encoded as vals == 0 so no branch is needed in the inner loop.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact runs
under the rust runtime. Real-TPU perf is estimated from the VMEM footprint
(BLOCK_ROWS*K*8B + N*4B) in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row tile. 256 rows x K=16 lanes x (4B cols + 4B vals) = 32 KiB of
# streamed operands per block plus the resident x vector — comfortably under
# a 4 MiB VMEM budget for all shipped (N, K) variants.
BLOCK_ROWS = 256


def _spmv_kernel(x_ref, cols_ref, vals_ref, o_ref):
    # x is the full vector (one VMEM-resident copy per block); cols/vals are
    # the current row tile. Gather + fma + lane-sum.
    x = x_ref[...]
    cols = cols_ref[...]
    vals = vals_ref[...]
    o_ref[...] = jnp.sum(vals * x[cols], axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def spmv_ell(x, cols, vals, *, block_rows=BLOCK_ROWS):
    """y[i] = sum_k vals[i,k] * x[cols[i,k]] via a row-tiled Pallas kernel.

    Requires N % block_rows == 0 (the AOT shapes guarantee this; tests also
    exercise the ragged fallback path in model.py).
    """
    n, k = cols.shape
    assert x.shape == (n,), (x.shape, n)
    if n % block_rows != 0:
        block_rows = n  # single-block fallback for small/ragged inputs
    grid = (n // block_rows,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),            # x: full, every block
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, cols, vals)
