"""L2: per-machine BSP superstep compute graphs, calling the L1 kernels.

These are the functions AOT-lowered to HLO text by aot.py and executed from
the rust simulator's hot path (rust/src/runtime/). Each takes an ELL-padded
local subgraph of a partition; the coordinator (L3) owns the cross-machine
replica exchange, dangling-mass bookkeeping and convergence checks.

Everything here is shape-static per (N, K) artifact variant — the rust side
pads the partition's local block to the nearest shipped variant.
"""

import jax
import jax.numpy as jnp

from .kernels import minplus_ell, spmv_ell
from .kernels.ref import INF


def pagerank_step(x, cols, vals, damping, teleport):
    """One local PageRank push superstep.

    new_rank = damping * (A_hat @ x) + teleport
    `teleport` folds (1-d)/N_global plus the per-superstep dangling-mass
    correction — both uniform scalars computed by L3. Returns (new_rank,).
    """
    y = spmv_ell(x, cols, vals)
    return (damping * y + teleport,)


def sssp_step(x, cols, wts, mask):
    """One local Bellman-Ford relaxation round. Returns (new_dist, changed).

    `changed` is the count of rows whose distance improved — L3 uses the
    per-machine counts to build the global frontier/termination signal
    without shipping the whole vector back every superstep.
    """
    y = minplus_ell(x, cols, wts, mask)
    changed = jnp.sum((y < x).astype(jnp.int32))
    return (y, changed)


def pagerank_step_ref(x, cols, vals, damping, teleport):
    """Pure-jnp L2 model (no Pallas) — oracle + ragged-shape fallback."""
    from .kernels import ref

    return (ref.pagerank_step(x, cols, vals, damping, teleport),)


def sssp_step_ref(x, cols, wts, mask):
    from .kernels import ref

    y = ref.minplus_ell(x, cols, wts, mask)
    return (y, jnp.sum((y < x).astype(jnp.int32)))


def example_args(n, k):
    """ShapeDtypeStructs for lowering a (n, k) variant."""
    f32 = jnp.float32
    return {
        "pagerank": (
            jax.ShapeDtypeStruct((n,), f32),        # x
            jax.ShapeDtypeStruct((n, k), jnp.int32),  # cols
            jax.ShapeDtypeStruct((n, k), f32),        # vals
            jax.ShapeDtypeStruct((), f32),            # damping
            jax.ShapeDtypeStruct((), f32),            # teleport
        ),
        "sssp": (
            jax.ShapeDtypeStruct((n,), f32),
            jax.ShapeDtypeStruct((n, k), jnp.int32),
            jax.ShapeDtypeStruct((n, k), f32),
            jax.ShapeDtypeStruct((n, k), f32),
        ),
    }


MODELS = {
    "pagerank": pagerank_step,
    "sssp": sssp_step,
}

__all__ = [
    "pagerank_step",
    "sssp_step",
    "pagerank_step_ref",
    "sssp_step_ref",
    "example_args",
    "MODELS",
    "INF",
]
