"""AOT: lower the L2 models to HLO *text* artifacts for the rust runtime.

HLO text, NOT serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the published
`xla` 0.1.6 crate) rejects (`proto.id() <= INT_MAX`). The text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Lowering uses return_tuple=True so the rust side unwraps with to_tupleN().

Each model is lowered at several static (N, K) ELL shape variants; the rust
runtime pads a partition's local block to the smallest fitting variant.
Artifact naming: artifacts/<model>_n<N>_k<K>.hlo.txt plus a manifest
artifacts/manifest.json the runtime reads at startup.
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model as m

# (N, K) variants shipped by default. N multiples of 256 (the kernel row
# tile); K covers the ELL widths the simulator produces after super-node row
# splitting (rust side splits rows with deg > K into chains of logical rows).
DEFAULT_VARIANTS = [(256, 8), (1024, 16), (4096, 16), (16384, 32)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(name, n, k):
    fn = m.MODELS[name]
    args = m.example_args(n, k)[name]
    return to_hlo_text(jax.jit(fn).lower(*args))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default=",".join(f"{n}x{k}" for n, k in DEFAULT_VARIANTS),
        help="comma-separated NxK list",
    )
    ap.add_argument("--models", default="pagerank,sssp")
    # Back-compat with the Makefile's single-file target.
    ap.add_argument("--out", default=None, help="also write a smoke model here")
    args = ap.parse_args(argv)

    variants = []
    for tok in args.variants.split(","):
        n, k = tok.lower().split("x")
        variants.append((int(n), int(k)))

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"models": {}}
    for name in args.models.split(","):
        entries = []
        for n, k in variants:
            text = lower_variant(name, n, k)
            fname = f"{name}_n{n}_k{k}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            entries.append({"n": n, "k": k, "file": fname})
            print(f"wrote {fname} ({len(text)} chars)", file=sys.stderr)
        manifest["models"][name] = entries

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)

    if args.out:
        # Smallest pagerank variant doubles as the Makefile's smoke artifact.
        n, k = variants[0]
        with open(args.out, "w") as f:
            f.write(lower_variant("pagerank", n, k))
        print(f"wrote {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
