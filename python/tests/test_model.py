# pytest: L2 model vs refs + full-algorithm convergence on small graphs.
#
# These validate the exact contracts the rust runtime depends on:
#   - pagerank_step/sssp_step output tuples and dtypes
#   - pagerank converges to the true dominant eigenvector on a known graph
#   - sssp `changed` counter semantics
#   - the AOT lowering path produces parseable HLO text for every variant

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from compile import model as m
from compile.aot import lower_variant
from compile.kernels import ref


def ell_from_edges(n, k, edges, pagerank=True):
    """Build (cols, vals/wts, mask) ELL from an undirected edge list."""
    adj = [[] for _ in range(n)]
    for u, v in edges:
        adj[u].append(v)
        adj[v].append(u)
    deg = [len(a) for a in adj]
    cols = np.zeros((n, k), np.int32)
    vals = np.zeros((n, k), np.float32)
    mask = np.zeros((n, k), np.float32)
    for i, nbrs in enumerate(adj):
        assert len(nbrs) <= k
        for j, c in enumerate(nbrs):
            cols[i, j] = c
            vals[i, j] = 1.0 / deg[c] if pagerank else 1.0
            mask[i, j] = 1.0
    return cols, vals, mask, deg


def test_pagerank_converges_star():
    # star graph: center 0, leaves 1..4. Known stationary distribution.
    n, k = 8, 4  # padded
    edges = [(0, 1), (0, 2), (0, 3), (0, 4)]
    cols, vals, _, deg = ell_from_edges(n, k, edges)
    d = 0.85
    nv = 5  # real vertices
    x = np.zeros(n, np.float32)
    x[:nv] = 1.0 / nv
    for _ in range(100):
        # padded rows have deg 0 -> they are "dangling" but hold rank 0
        teleport = (1 - d) / nv
        (x_new,) = m.pagerank_step(
            jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
            jnp.float32(d), jnp.float32(teleport),
        )
        x = np.array(x_new)
        x[nv:] = 0.0
    # closed form: center = (1-d+4*d*c_leaf*1)/... — verify via dense power iteration
    P = np.zeros((nv, nv))
    for u, v in edges:
        P[u, v] = 1.0 / deg[v]
        P[v, u] = 1.0 / deg[u]
    y = np.full(nv, 1.0 / nv)
    for _ in range(100):
        y = d * P @ y + (1 - d) / nv
    np.testing.assert_allclose(x[:nv], y, rtol=1e-4)


def test_pagerank_step_matches_ref_model():
    rng = np.random.default_rng(7)
    n, k = 256, 8
    cols = rng.integers(0, n, (n, k)).astype(np.int32)
    mask = (rng.random((n, k)) < 0.5).astype(np.float32)
    vals = rng.random((n, k)).astype(np.float32) * mask
    x = rng.random(n).astype(np.float32)
    a = m.pagerank_step(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
                        jnp.float32(0.85), jnp.float32(0.01))[0]
    b = m.pagerank_step_ref(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals),
                            jnp.float32(0.85), jnp.float32(0.01))[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_sssp_changed_counter():
    n, k = 8, 2
    edges = [(0, 1), (1, 2), (2, 3)]
    cols, wts, mask, _ = ell_from_edges(n, k, edges, pagerank=False)
    x = np.full(n, 1e30, np.float32)
    x[0] = 0.0
    dist, changed = m.sssp_step(jnp.asarray(x), jnp.asarray(cols),
                                jnp.asarray(wts), jnp.asarray(mask))
    assert int(changed) == 1  # only node 1 improves in round one
    dist2, changed2 = m.sssp_step(dist, jnp.asarray(cols),
                                  jnp.asarray(wts), jnp.asarray(mask))
    assert int(changed2) == 1  # node 2
    assert float(dist2[1]) == 1.0 and float(dist2[2]) == 2.0


def test_sssp_fixpoint_changed_zero():
    n, k = 8, 2
    edges = [(0, 1), (1, 2)]
    cols, wts, mask, _ = ell_from_edges(n, k, edges, pagerank=False)
    x = np.array([0, 1, 2, 0, 0, 0, 0, 0], np.float32)
    x[3:] = float(ref.INF)
    _, changed = m.sssp_step(jnp.asarray(x), jnp.asarray(cols),
                             jnp.asarray(wts), jnp.asarray(mask))
    assert int(changed) == 0


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_sssp_model_matches_ref(seed):
    rng = np.random.default_rng(seed)
    n, k = 256, 6
    cols = rng.integers(0, n, (n, k)).astype(np.int32)
    mask = (rng.random((n, k)) < 0.6).astype(np.float32)
    wts = rng.random((n, k)).astype(np.float32) * 9
    x = rng.random(n).astype(np.float32) * 50
    a, ca = m.sssp_step(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(wts), jnp.asarray(mask))
    b, cb = m.sssp_step_ref(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(wts), jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)
    assert int(ca) == int(cb)


@pytest.mark.parametrize("name", ["pagerank", "sssp"])
@pytest.mark.parametrize("n,k", [(256, 8), (1024, 16)])
def test_aot_lowering_produces_hlo(name, n, k):
    text = lower_variant(name, n, k)
    assert "HloModule" in text
    assert "ENTRY" in text
    # static shapes visible in the HLO signature
    assert f"{n},{k}" in text.replace(" ", "") or f"[{n},{k}]" in text
