# pytest: Pallas kernels vs pure-jnp oracles — the CORE correctness signal.
#
# hypothesis sweeps shapes, ELL widths, padding patterns and value ranges;
# deterministic tests pin down the exact padding conventions (vals==0 for
# SpMV, mask==0 -> INF for min-plus) and known-answer graphs.

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from compile.kernels import minplus_ell, ref, spmv_ell
from compile.kernels.ref import INF


def make_ell(rng, n, k, density=0.7, wmax=10.0):
    """Random ELL block: (cols, vals, mask) with vals zeroed on padding."""
    cols = rng.integers(0, n, size=(n, k)).astype(np.int32)
    mask = (rng.random((n, k)) < density).astype(np.float32)
    vals = (rng.random((n, k)).astype(np.float32) * wmax) * mask
    return cols, vals, mask


# --------------------------------------------------------------------------
# hypothesis sweeps
# --------------------------------------------------------------------------

block_sizes = st.sampled_from([1, 2, 4, 8])  # block_rows divisors of n
shapes = st.tuples(
    st.sampled_from([8, 16, 64, 256, 512]),  # n
    st.integers(min_value=1, max_value=9),   # k
)


@settings(max_examples=25, deadline=None)
@given(shape=shapes, seed=st.integers(0, 2**31 - 1))
def test_spmv_matches_ref(shape, seed):
    n, k = shape
    rng = np.random.default_rng(seed)
    cols, vals, _ = make_ell(rng, n, k)
    x = rng.standard_normal(n).astype(np.float32)
    block = min(n, 256) if n % 256 == 0 else n
    got = spmv_ell(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals), block_rows=block)
    want = ref.spmv_ell(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(shape=shapes, seed=st.integers(0, 2**31 - 1))
def test_minplus_matches_ref(shape, seed):
    n, k = shape
    rng = np.random.default_rng(seed)
    cols, wts, mask = make_ell(rng, n, k, wmax=5.0)
    x = (rng.random(n).astype(np.float32) * 100.0)
    x[rng.integers(0, n)] = 0.0  # a source
    block = min(n, 256) if n % 256 == 0 else n
    got = minplus_ell(
        jnp.asarray(x), jnp.asarray(cols), jnp.asarray(wts), jnp.asarray(mask),
        block_rows=block,
    )
    want = ref.minplus_ell(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(wts), jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), dtype_bits=st.sampled_from([32]))
def test_spmv_zero_padding_is_inert(seed, dtype_bits):
    # Padding entries (vals == 0) must not change the result no matter what
    # garbage their column indices hold.
    n, k = 64, 6
    rng = np.random.default_rng(seed)
    cols, vals, mask = make_ell(rng, n, k, density=0.4)
    x = rng.standard_normal(n).astype(np.float32)
    scrambled = cols.copy()
    pad = mask == 0
    scrambled[pad] = rng.integers(0, n, size=pad.sum())
    a = spmv_ell(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals), block_rows=n)
    b = spmv_ell(jnp.asarray(x), jnp.asarray(scrambled), jnp.asarray(vals), block_rows=n)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# --------------------------------------------------------------------------
# deterministic known-answer tests
# --------------------------------------------------------------------------

def test_spmv_known_triangle():
    # 3-cycle with uniform weights 1/deg = 1/2: pagerank push of uniform x
    # returns uniform.
    n, k = 4, 2  # padded to 4 rows, row 3 is padding
    cols = np.array([[1, 2], [0, 2], [0, 1], [0, 0]], np.int32)
    vals = np.full((n, k), 0.5, np.float32)
    vals[3] = 0.0
    x = np.array([1 / 3, 1 / 3, 1 / 3, 0.0], np.float32)
    y = np.asarray(spmv_ell(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(vals), block_rows=n))
    np.testing.assert_allclose(y[:3], [1 / 3] * 3, rtol=1e-6)
    assert y[3] == 0.0


def test_minplus_path_graph():
    # path 0-1-2-3 with unit weights, source at 0: one relaxation round
    # improves every node adjacent to a settled one.
    n, k = 4, 2
    cols = np.array([[1, 0], [0, 2], [1, 3], [2, 0]], np.int32)
    mask = np.array([[1, 0], [1, 1], [1, 1], [1, 0]], np.float32)
    wts = mask.copy()
    x = np.array([0.0, 1e30, 1e30, 1e30], np.float32)
    y = np.asarray(minplus_ell(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(wts),
                               jnp.asarray(mask), block_rows=n))
    assert y[0] == 0.0
    assert y[1] == 1.0
    assert y[2] > 1e29 and y[3] > 1e29  # not yet reached


def test_minplus_padding_is_inert():
    # fully-masked row keeps its own value
    n, k = 2, 3
    cols = np.zeros((n, k), np.int32)
    mask = np.zeros((n, k), np.float32)
    wts = np.zeros((n, k), np.float32)
    x = np.array([5.0, 7.0], np.float32)
    y = np.asarray(minplus_ell(jnp.asarray(x), jnp.asarray(cols), jnp.asarray(wts),
                               jnp.asarray(mask), block_rows=n))
    np.testing.assert_allclose(y, x)


def test_inf_sentinel_below_f32_max():
    assert float(INF) < np.finfo(np.float32).max
