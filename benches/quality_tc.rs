//! Bench: partition-quality regeneration — Figure 8 (ablation), Figure 12
//! (comparison), Figures 13–15 (scalability) at bench scale, timing each
//! table's end-to-end production.
//!
//!     cargo bench --bench quality_tc
//!
//! Paper shape to check: WindGP lowest ln TC everywhere; each ablation
//! stage helps; slope < others in fig13; TC flattens past the fig14
//! saturation point; homogeneous (1-type) is the fig15 minimum.

use windgp::experiments::{self, ExpCtx};
use windgp::util::bench::bench;

fn main() {
    let shrink: u32 = std::env::var("BENCH_SHRINK")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let ctx = ExpCtx::new(1, shrink);
    for id in ["fig8", "fig12", "fig13", "fig14", "fig15"] {
        let mut out = String::new();
        bench(&format!("experiment/{id} (shrink {shrink})"), 1, || {
            out = experiments::run(id, &ctx).unwrap();
        });
        println!("{out}");
    }
}
