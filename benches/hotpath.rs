//! Bench: hot-path micro-benchmarks for the §Perf optimization loop.
//!
//!     cargo bench --bench hotpath
//!
//! Covers each layer's inner loop:
//!   L3 expansion  — best-first claims per second (heap + bitmap path)
//!   L3 tracker    — incremental edge moves per second (SLS inner loop)
//!   L3 sls        — one destroy-repair round
//!   L1/L2 kernels — ELL SpMV / min-plus rows per second, pure vs PJRT

use windgp::graph::rmat::{generate, RmatParams};
use windgp::machines::Cluster;
use windgp::partition::{CostTracker, EdgePartition};
#[cfg(feature = "pjrt")]
use windgp::runtime::{PjrtBackend, PjrtEngine};
use windgp::simulator::ell::{EllBackend, EllBlock, PureBackend};
use windgp::simulator::SimGraph;
use windgp::util::bench::{bench, throughput};
use windgp::util::SplitMix64;
use windgp::windgp::expand::{ExpandParams, Expander};
use windgp::windgp::WindGP;
use windgp::partition::Partitioner;

fn main() {
    let g = generate(&RmatParams::graph500(15, 16), 11);
    let m = g.num_edges();
    println!("graph: |V|={} |E|={}", g.num_vertices(), m);
    let cluster = Cluster::heterogeneous_small(3, 6, (m as f64) / 1.6e7);

    // --- expansion engine ---
    let s = bench("expand: full graph, best-first", 3, || {
        let mut ex = Expander::new(&g, &cluster, 1);
        let params = ExpandParams { alpha: 0.3, beta: 0.3 };
        let mut total = 0usize;
        for i in 0..9u32 {
            total += ex
                .expand_partition(i, (m as u64) / 9 + 1, &params)
                .len();
        }
        assert!(total > m / 2);
    });
    println!("  -> {:.2}M edge-claims/s", throughput(m, s.mean) / 1e6);

    // --- incremental tracker ---
    let mut rng = SplitMix64::new(3);
    let assignment: Vec<u32> = (0..m).map(|_| rng.next_usize(9) as u32).collect();
    let ep = EdgePartition::from_assignment(9, assignment);
    let mut t = CostTracker::new(&g, &cluster, &ep);
    let moves: Vec<(u32, u32)> = (0..200_000)
        .map(|_| (rng.next_usize(m) as u32, rng.next_usize(9) as u32))
        .collect();
    let s = bench("tracker: 200K random edge moves", 3, || {
        for &(e, p) in &moves {
            t.move_edge(e, p);
        }
    });
    println!("  -> {:.2}M moves/s", throughput(moves.len(), s.mean) / 1e6);

    // --- one full WindGP run (the headline partitioner) ---
    let s = bench("windgp: full pipeline", 3, || {
        let ep = WindGP::default().partition(&g, &cluster, 1);
        assert!(ep.is_complete());
    });
    println!("  -> {:.2}M edges partitioned/s", throughput(m, s.mean) / 1e6);

    // --- kernels ---
    let wind = WindGP::default();
    let ep = wind.partition(&g, &cluster, 1);
    let sg = SimGraph::build(&g, &cluster, &ep);
    let l = &sg.locals[0];
    let blk = EllBlock::build(l, 16, None, |_, _| 0.5);
    let x = blk.fill_x(&vec![1.0; blk.verts], 0.0);
    let mut pure = PureBackend;
    let s = bench(
        &format!("ell spmv pure ({} rows x {})", blk.rows, blk.k),
        5,
        || {
            let y = pure.spmv(0, &blk, &x);
            assert_eq!(y.len(), blk.rows);
        },
    );
    println!("  -> {:.1}M lanes/s", throughput(blk.rows * blk.k, s.mean) / 1e6);

    #[cfg(feature = "pjrt")]
    {
        if PjrtEngine::default_dir().join("manifest.json").exists() {
            let engine = PjrtEngine::load(PjrtEngine::default_dir()).unwrap();
            let mut be = PjrtBackend::new(engine);
            // pick an artifact-shaped block
            let (k, pad) = be.chooser("pagerank")(l);
            if let Some(n) = pad {
                let blk = EllBlock::build(l, k, Some(n), |_, _| 0.5);
                let x = blk.fill_x(&vec![1.0; blk.verts], 0.0);
                let s = bench(
                    &format!("ell spmv PJRT ({} rows x {})", blk.rows, blk.k),
                    5,
                    || {
                        let y = be.spmv(0, &blk, &x);
                        assert_eq!(y.len(), blk.rows);
                    },
                );
                println!(
                    "  -> {:.1}M lanes/s ({} pjrt calls)",
                    throughput(blk.rows * blk.k, s.mean) / 1e6,
                    be.pjrt_calls
                );
            }
        } else {
            println!("(PJRT kernel bench skipped: run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(PJRT kernel bench skipped: build with `--features pjrt`)");
}
