//! Bench: hot-path micro-benchmarks for the §Perf optimization loop.
//!
//!     cargo bench --bench hotpath
//!
//! Covers each layer's inner loop:
//!   L3 expansion  — best-first claims per second (heap + bitmap path)
//!   L3 tracker    — incremental edge moves per second (SLS inner loop)
//!   L3 sls        — one destroy-repair round
//!   L1/L2 kernels — ELL SpMV / min-plus rows per second, pure vs PJRT

use windgp::graph::rmat::{generate, RmatParams};
use windgp::machines::Cluster;
use windgp::partition::{CostTracker, EdgePartition};
#[cfg(feature = "pjrt")]
use windgp::runtime::{PjrtBackend, PjrtEngine};
use windgp::simulator::ell::{EllBackend, EllBlock, PureBackend, INF};
use windgp::simulator::simd::{SimdBackend, SimdMode};
use windgp::simulator::SimGraph;
use windgp::util::bench::{bench, throughput};
use windgp::util::SplitMix64;
use windgp::windgp::expand::{ExpandParams, Expander};
use windgp::windgp::WindGP;
use windgp::partition::Partitioner;

fn main() {
    let g = generate(&RmatParams::graph500(15, 16), 11);
    let m = g.num_edges();
    println!("graph: |V|={} |E|={}", g.num_vertices(), m);
    let cluster = Cluster::heterogeneous_small(3, 6, (m as f64) / 1.6e7);

    // --- expansion engine ---
    let s = bench("expand: full graph, best-first", 3, || {
        let mut ex = Expander::new(&g, &cluster, 1);
        let params = ExpandParams { alpha: 0.3, beta: 0.3 };
        let mut total = 0usize;
        for i in 0..9u32 {
            total += ex
                .expand_partition(i, (m as u64) / 9 + 1, &params)
                .len();
        }
        assert!(total > m / 2);
    });
    println!("  -> {:.2}M edge-claims/s", throughput(m, s.mean) / 1e6);

    // --- incremental tracker ---
    let mut rng = SplitMix64::new(3);
    let assignment: Vec<u32> = (0..m).map(|_| rng.next_usize(9) as u32).collect();
    let ep = EdgePartition::from_assignment(9, assignment);
    let t0 = CostTracker::new(&g, &cluster, &ep);
    let moves: Vec<(u32, u32)> = (0..200_000)
        .map(|_| (rng.next_usize(m) as u32, rng.next_usize(9) as u32))
        .collect();
    let s = bench("tracker: 200K random edge moves", 3, || {
        // fresh snapshot per sample so every replay measures the same state
        let mut t = t0.clone();
        for &(e, p) in &moves {
            t.move_edge(e, p);
        }
    });
    println!("  -> {:.2}M moves/s", throughput(moves.len(), s.mean) / 1e6);

    // --- ingest: parallel parse + build vs the sequential builder ---
    {
        use windgp::graph::{ingest, io as graph_io, GraphBuilder};
        let dir = std::env::temp_dir().join("windgp_hotpath_ingest");
        std::fs::create_dir_all(&dir).unwrap();
        let txt = dir.join("hotpath.txt");
        graph_io::write_edge_list(&g, &txt).unwrap();
        let bytes = std::fs::read(&txt).unwrap();
        let s = bench("ingest: chunked text parse", 3, || {
            let parsed = ingest::parse_text(&bytes, 0).unwrap();
            let total: usize = parsed.chunks.iter().map(|c| c.len()).sum();
            assert_eq!(total, m);
        });
        println!("  -> {:.2}M edges parsed/s", throughput(m, s.mean) / 1e6);
        let mut raw = g.edges_vec();
        rng.shuffle(&mut raw);
        let s = bench("ingest: parallel build (merge + CSR)", 3, || {
            let gb = ingest::build_parallel(raw.clone(), 0, 0);
            assert_eq!(gb.num_edges(), m);
        });
        println!("  -> {:.2}M edges built/s", throughput(m, s.mean) / 1e6);
        let s = bench("ingest: sequential build (GraphBuilder)", 3, || {
            let mut b = GraphBuilder::with_capacity(raw.len());
            for &(u, v) in &raw {
                b.add_edge(u, v);
            }
            assert_eq!(b.build(0).num_edges(), m);
        });
        println!("  -> {:.2}M edges built/s", throughput(m, s.mean) / 1e6);
        let bin = dir.join("hotpath.bin");
        graph_io::write_binary(&g, &bin).unwrap();
        let s = bench("ingest: binary cache v2 reload", 3, || {
            let g2 = graph_io::read_binary(&bin).unwrap();
            assert_eq!(g2.num_edges(), m);
        });
        println!("  -> {:.2}M edges reloaded/s", throughput(m, s.mean) / 1e6);
    }

    // --- one full WindGP run (the headline partitioner) ---
    let s = bench("windgp: full pipeline", 3, || {
        let ep = WindGP::default().partition(&g, &cluster, 1);
        assert!(ep.is_complete());
    });
    println!("  -> {:.2}M edges partitioned/s", throughput(m, s.mean) / 1e6);

    // --- kernels ---
    let wind = WindGP::default();
    let ep = wind.partition(&g, &cluster, 1);
    let sg = SimGraph::build(&g, &cluster, &ep);
    let l = &sg.locals[0];
    let blk = EllBlock::build(l, 16, None, |_, _| 0.5);
    let x = blk.fill_x(&vec![1.0; blk.verts], 0.0);
    let mut pure = PureBackend;
    let s = bench(
        &format!("ell spmv pure ({} rows x {})", blk.rows, blk.k),
        5,
        || {
            let y = pure.spmv(0, &blk, &x);
            assert_eq!(y.len(), blk.rows);
        },
    );
    println!("  -> {:.1}M lanes/s", throughput(blk.rows * blk.k, s.mean) / 1e6);

    // scalar (branchless, lane-unrolled) vs SIMD path of the SimdBackend —
    // all bitwise-identical to the pure oracle, so the delta is raw speed
    let mut scalar_be = SimdBackend::new(SimdMode::Scalar);
    let mut simd_be = SimdBackend::new(SimdMode::Auto);
    let x_inf = blk.fill_x(&vec![1.0; blk.verts], INF);
    let s = bench("ell spmv scalar", 5, || {
        let y = scalar_be.spmv(0, &blk, &x);
        assert_eq!(y.len(), blk.rows);
    });
    println!("  -> {:.1}M lanes/s", throughput(blk.rows * blk.k, s.mean) / 1e6);
    let s = bench(&format!("ell spmv simd ({})", simd_be.active()), 5, || {
        let y = simd_be.spmv(0, &blk, &x);
        assert_eq!(y.len(), blk.rows);
    });
    println!("  -> {:.1}M lanes/s", throughput(blk.rows * blk.k, s.mean) / 1e6);
    let s = bench("ell minplus scalar", 5, || {
        let y = scalar_be.minplus(0, &blk, &x_inf);
        assert_eq!(y.len(), blk.rows);
    });
    println!("  -> {:.1}M lanes/s", throughput(blk.rows * blk.k, s.mean) / 1e6);
    let s = bench(&format!("ell minplus simd ({})", simd_be.active()), 5, || {
        let y = simd_be.minplus(0, &blk, &x_inf);
        assert_eq!(y.len(), blk.rows);
    });
    println!("  -> {:.1}M lanes/s", throughput(blk.rows * blk.k, s.mean) / 1e6);

    #[cfg(feature = "pjrt")]
    {
        if PjrtEngine::default_dir().join("manifest.json").exists() {
            let engine = PjrtEngine::load(PjrtEngine::default_dir()).unwrap();
            let mut be = PjrtBackend::new(engine);
            // pick an artifact-shaped block
            let (k, pad) = be.chooser("pagerank")(l);
            if let Some(n) = pad {
                let blk = EllBlock::build(l, k, Some(n), |_, _| 0.5);
                let x = blk.fill_x(&vec![1.0; blk.verts], 0.0);
                let s = bench(
                    &format!("ell spmv PJRT ({} rows x {})", blk.rows, blk.k),
                    5,
                    || {
                        let y = be.spmv(0, &blk, &x);
                        assert_eq!(y.len(), blk.rows);
                    },
                );
                println!(
                    "  -> {:.1}M lanes/s ({} pjrt calls)",
                    throughput(blk.rows * blk.k, s.mean) / 1e6,
                    be.pjrt_calls
                );
            }
        } else {
            println!("(PJRT kernel bench skipped: run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(PJRT kernel bench skipped: build with `--features pjrt`)");
}
