//! Bench: distributed-execution simulation — Table 1 (TC vs runtime) and
//! Tables 13–17 (§5.4) at bench scale, plus raw simulator throughput.
//!
//!     cargo bench --bench distributed_sim
//!
//! Paper shape to check: WindGP lowest simulated time on every workload;
//! PageRank speedups exceed SSSP speedups; hetero baselines each lose on
//! the axis they ignore.

use windgp::coordinator::{run_job, Job, Workload};
use windgp::experiments::{self, ExpCtx};
use windgp::partition::Partitioner;
use windgp::util::bench::{bench, throughput};
use windgp::windgp::WindGP;

fn main() {
    let shrink: u32 = std::env::var("BENCH_SHRINK")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let ctx = ExpCtx::new(1, shrink);

    println!("== simulator throughput (edges processed per second) ==");
    let g = ctx.graph("lj-s");
    let cluster = ctx.nine_machine_for("lj-s", &g);
    let wind = WindGP::default();
    let ep = wind.partition(&g, &cluster, 1);
    let sg = windgp::simulator::SimGraph::build(&g, &cluster, &ep);
    let mut be = windgp::simulator::ell::PureBackend;
    let iters = 5;
    let s = bench("pagerank 5 supersteps (pure)", 3, || {
        let _ = windgp::simulator::algorithms::pagerank(&sg, iters, &mut be);
    });
    println!(
        "  -> {:.1}M edge-ops/s\n",
        throughput(g.num_edges() * iters, s.mean) / 1e6
    );

    for id in ["table1", "table13", "table14", "table15", "table16", "table17"] {
        let mut out = String::new();
        bench(&format!("experiment/{id} (shrink {shrink})"), 1, || {
            out = experiments::run(id, &ctx).unwrap();
        });
        println!("{out}");
    }

    println!("== end-to-end job pipeline (partition + 3 workloads) ==");
    let job = Job {
        g: &g,
        cluster: &cluster,
        partitioner: &wind,
        seed: 1,
        workloads: vec![
            Workload::PageRank { iters: 5 },
            Workload::Sssp { source: 0 },
            Workload::Triangle,
        ],
        workers: 0,
    };
    bench("run_job windgp lj-s", 2, || {
        let rep = run_job(&job, None);
        assert!(rep.cost.all_feasible());
    });
}
