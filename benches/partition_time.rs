//! Bench: partitioning wall time — regenerates Table 11 (traditional
//! methods) and Table 18 (heterogeneous methods) as timing runs.
//!
//!     cargo bench --bench partition_time
//!
//! Paper shape to check: all methods within one order of magnitude;
//! WindGP ≈ NE (paper: 11% slower); HDRF fastest of the quality methods;
//! METIS slowest.

use windgp::experiments::{common, ExpCtx};
use windgp::util::bench::bench;

fn main() {
    let shrink: u32 = std::env::var("BENCH_SHRINK")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let ctx = ExpCtx::new(1, shrink);
    println!("== Table 11: traditional methods (shrink {shrink}) ==");
    for name in ["co-s", "lj-s", "po-s", "cp-s", "rn-s"] {
        let g = ctx.graph(name);
        let cluster = ctx.cluster_for(name, &g);
        for a in common::traditional_partitioners() {
            bench(&format!("{name}/{}", a.name()), 3, || {
                let ep = a.partition(&g, &cluster, 1);
                assert!(ep.is_complete());
            });
        }
    }
    println!("\n== Table 18: heterogeneous methods on large stand-ins ==");
    for name in common::BIG {
        let g = ctx.graph(name);
        let cluster = ctx.nine_machine_for(name, &g);
        for a in common::hetero_partitioners() {
            bench(&format!("{name}/{}", a.name()), 3, || {
                let ep = a.partition(&g, &cluster, 1);
                assert!(ep.is_complete());
            });
        }
    }
}
