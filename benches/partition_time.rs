//! Bench: partitioning wall time — regenerates Table 11 (traditional
//! methods) and Table 18 (heterogeneous methods) as timing runs.
//!
//!     cargo bench --bench partition_time
//!
//! Paper shape to check: all methods within one order of magnitude;
//! WindGP ≈ NE (paper: 11% slower); HDRF fastest of the quality methods;
//! METIS slowest.

use windgp::graph::Graph;
use windgp::machines::Cluster;
use windgp::partition::Partitioner;
use windgp::util::bench::bench;

use windgp::experiments::{common, ExpCtx};

/// Bench one partitioner with a drift guard: every sample runs on fresh
/// internal state (each `partition` call builds its own `Expander` /
/// tracker — same bug class as the tracker bench fixed in PR 2, where
/// replaying on a persistent instance measured ever-drifting state). The
/// two-sample stability assertion pins that statelessness: if a
/// partitioner ever leaks state across calls, sample 2 diverges and this
/// fails before any timing is reported.
fn bench_partitioner(label: &str, a: &dyn Partitioner, g: &Graph, cluster: &Cluster) {
    let first = a.partition(g, cluster, 1);
    let second = a.partition(g, cluster, 1);
    assert!(first.is_complete());
    assert_eq!(
        first.assignment, second.assignment,
        "{label}: samples are not independent (state drifts across calls)"
    );
    bench(label, 3, || {
        let ep = a.partition(g, cluster, 1);
        assert!(ep.is_complete());
    });
}

fn main() {
    let shrink: u32 = std::env::var("BENCH_SHRINK")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let ctx = ExpCtx::new(1, shrink);
    println!("== Table 11: traditional methods (shrink {shrink}) ==");
    for name in ["co-s", "lj-s", "po-s", "cp-s", "rn-s"] {
        let g = ctx.graph(name);
        let cluster = ctx.cluster_for(name, &g);
        for a in common::traditional_partitioners() {
            bench_partitioner(&format!("{name}/{}", a.name()), a.as_ref(), &g, &cluster);
        }
    }
    println!("\n== Table 18: heterogeneous methods on large stand-ins ==");
    for name in common::BIG {
        let g = ctx.graph(name);
        let cluster = ctx.nine_machine_for(name, &g);
        for a in common::hetero_partitioners() {
            bench_partitioner(&format!("{name}/{}", a.name()), a.as_ref(), &g, &cluster);
        }
    }
}
